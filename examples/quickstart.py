"""Quickstart: LITE fine-tune a mini code model, generate with early exit.

    PYTHONPATH=src python examples/quickstart.py

Runs in ~2 minutes on CPU: synthetic Java corpus -> LITE fine-tune (Eq. 1)
-> greedy generation with a fixed early exit -> energy savings report.
"""
import jax.numpy as jnp
import numpy as np

from repro.api import PolicySpec
from repro.configs.llama32_3b import paper_mini
from repro.core import energy
from repro.core.early_exit import generate
from repro.core.exit_points import exit_points
from repro.data import CodeCompletionDataset
from repro.training import train_model


def main():
    cfg = paper_mini(num_layers=12, d_model=192, vocab_size=2048)
    print(f"model: {cfg.name}  exit points: {exit_points(cfg)}")
    ds = CodeCompletionDataset(language="java", n_files=120, seq_len=256,
                               vocab_size=2048)
    print("LITE fine-tuning (aggregated loss over exit layers) ...")
    params, hist = train_model(cfg, ds, kind="lite", steps=60,
                               batch_size=4, lr=1e-3, log_every=20)

    tasks = ds.completion_tasks("test", 4, max_context=96)
    ctx = np.zeros((4, 96), np.int32)
    for j, (c, _) in enumerate(tasks):
        ctx[j, 96 - len(c):] = c
    ctx = jnp.asarray(ctx)

    for name, spec in [("full model", PolicySpec("none")),
                       ("early exit @4", PolicySpec("fixed",
                                                    {"exit_idx": 0}))]:
        out = generate(params, cfg, ctx, 12, policy=spec)
        exits = np.asarray(out["exit_layers"])
        stats = energy.summarize_exit_energy(cfg, 96, exits)
        txt = ds.tokenizer.decode(np.asarray(out["tokens"])[0].tolist())
        print(f"\n[{name}] mean layers {stats['mean_layers_used']:.1f}"
              f"/{cfg.num_layers}, energy saving "
              f"{stats['energy_saving_frac']*100:.1f}%")
        print(f"  sample completion: {txt!r}")


if __name__ == "__main__":
    main()
