"""End-to-end driver (paper Fig. 2, offline phase): a ~100M-param model is
LITE fine-tuned for a few hundred steps, an RL exit agent is PPO-trained on
cached rollouts, and the resulting GREEN-CODE pipeline is evaluated against
the full-depth baselines at several thresholds.

    PYTHONPATH=src python examples/finetune_and_rl.py [--steps 200]
                     [--small]  # 13M variant for quick runs
"""
import argparse

import numpy as np

from repro.api import PolicySpec
from repro.configs.llama32_3b import paper_mini
from repro.data import CodeCompletionDataset
from repro.rl import PPOConfig, RewardCoefs, agent_policy_spec, train_agent
from repro.serving import Engine
from repro.serving.metrics import aggregate_metrics, rouge_l
from repro.training import save_pytree, train_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ppo-steps", type=int, default=100_000)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    if args.small:
        cfg = paper_mini(num_layers=12, d_model=192, vocab_size=2048)
    else:
        # ~100M params: 20L x d512 x ff2048, vocab 4096
        cfg = paper_mini(num_layers=20, d_model=512, vocab_size=4096)
    print(f"model {cfg.name}: ~{cfg.param_count()/1e6:.0f}M params")

    ds = CodeCompletionDataset(language="java", n_files=240, seq_len=256,
                               vocab_size=cfg.vocab_size)
    print(f"[1/3] LITE fine-tune ({args.steps} steps)")
    params, _ = train_model(cfg, ds, kind="lite", steps=args.steps,
                            batch_size=4, lr=5e-4, log_every=25)

    print("[2/3] PPO exit agent on cached rollouts")
    agent, hist, cache = train_agent(
        params, cfg, ds, n_episodes=32, gen_tokens=10,
        coefs=RewardCoefs(beta=1.0, gamma=1.0),
        ppo=PPOConfig(total_steps=args.ppo_steps, horizon=128, n_lanes=16),
        log_every=10)
    print(f"  final mean step reward: {hist[-1]['mean_step_reward']:+.3f}")

    print("[3/3] evaluation")
    tasks = ds.completion_tasks("test", 24, max_context=160)
    vocab = ds.tokenizer.vocab
    eng = Engine(params, cfg, max_new=15, max_context=160,
                 agent_params=agent)
    for name, spec in [
            ("full", PolicySpec("none")),
            ("GC(0.6)", agent_policy_spec(threshold=0.6)),
            ("GC(0.9)", agent_policy_spec(threshold=0.9))]:
        res = eng.serve([c for c, _ in tasks], policy=spec)
        scores = [rouge_l([vocab[i] for i in hyp if i < len(vocab)],
                          [vocab[i] for i in ref[:15] if i < len(vocab)])
                  for (_, ref), hyp in zip(tasks, res.tokens)]
        agg = aggregate_metrics(res.metrics)
        print(f"  {name:9s} rougeL {np.mean(scores):.3f}  layers "
              f"{agg['mean_layers']:5.2f}/{cfg.num_layers}  energy saving "
              f"{agg['energy_saving_frac']*100:5.1f}%")

    if args.ckpt_dir:
        save_pytree(params, f"{args.ckpt_dir}/model")
        save_pytree(agent, f"{args.ckpt_dir}/agent")
        print(f"checkpoints -> {args.ckpt_dir}")


if __name__ == "__main__":
    main()
